"""Unit + property tests for the three splitting strategies (Algs. 3, 5, 8)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests.conftest import hypothesis_or_stubs

given, settings, st = hypothesis_or_stubs()

from repro.core import (compute_beta, compute_r, split_bitmask, split_rn,
                        split_rn_const, split_oz2, split_oz2_bitmask,
                        split_oz2_fast2, split_oz2_bitmask_fast2,
                        reconstruct, residual)
from tests.conftest import make_phi_matrix

SPLITTERS = {"bitmask": split_bitmask, "rn": split_rn, "rn_const": split_rn_const}
OZ2_SPLITTERS = {"oz2_rn": split_oz2, "oz2_bitmask": split_oz2_bitmask}
FAST2_SPLITTERS = {"oz2_rn_fast2": split_oz2_fast2,
                   "oz2_bitmask_fast2": split_oz2_bitmask_fast2}
ALL_SPLITTERS = {**SPLITTERS, **OZ2_SPLITTERS, **FAST2_SPLITTERS}
# digit magnitude budget per splitter: truncation spans the full
# +-(2^beta - 1) range, round-to-nearest half of it
DIGIT_LIMIT = {
    "bitmask": lambda beta: 2 ** beta - 1,
    "oz2_bitmask": lambda beta: 2 ** beta - 1,
    "oz2_bitmask_fast2": lambda beta: 2 ** beta - 1,
    "rn": lambda beta: 2 ** (beta - 1),
    "rn_const": lambda beta: 2 ** (beta - 1),
    "oz2_rn": lambda beta: 2 ** (beta - 1),
    "oz2_rn_fast2": lambda beta: 2 ** (beta - 1),
}


def test_compute_beta_matches_paper():
    # beta = min(7, floor((31 - log2 n)/2)), eq. (4)
    assert compute_beta(256) == 7
    assert compute_beta(1024) == 7
    assert compute_beta(2**17) == 7
    assert compute_beta(2**18) == 6       # (31-18)//2
    assert compute_beta(2**20) == 5
    assert compute_beta(2**29) == 1
    with pytest.raises(ValueError):
        compute_beta(2**30)


def test_compute_r_matches_paper():
    # r = max(1, 2^(31 - 2 beta - ceil(log2 n))), eq. (12)
    assert compute_r(4096, 7) == 2 ** (31 - 14 - 12)
    assert compute_r(256, 7) == 2 ** (31 - 14 - 8)
    assert compute_r(2**20, 5) == 2 ** (31 - 10 - 20)
    assert compute_r(2**29, 1) == 1


@pytest.mark.parametrize("name", list(SPLITTERS))
@pytest.mark.parametrize("axis", [0, 1])
def test_digit_ranges(rng, name, axis):
    a = jnp.asarray(make_phi_matrix(rng, 32, 48, phi=1.0))
    s = SPLITTERS[name](a, 8, axis=axis)
    d = np.asarray(s.digits, dtype=np.int32)
    if name == "bitmask":
        assert np.max(np.abs(d)) <= 2 ** s.beta - 1          # eq. (5) digits
    else:
        assert np.max(np.abs(d)) <= 2 ** (s.beta - 1)        # RN digits
    assert s.digits.dtype == jnp.int8


@pytest.mark.parametrize("name", list(SPLITTERS))
def test_scales_are_powers_of_two(rng, name):
    a = jnp.asarray(make_phi_matrix(rng, 16, 64, phi=2.0))
    s = SPLITTERS[name](a, 6)
    sc = np.asarray(s.scale)
    m, e = np.frexp(sc[sc != 0])
    assert np.all(m == 0.5)


@pytest.mark.parametrize("name,k", [("bitmask", 8), ("rn", 8), ("rn_const", 8)])
def test_residual_decreases_geometrically(rng, name, k):
    """|V_s| < 2^(-beta s + 1) g e^T — eq. (16)-ish contraction per slice."""
    a = jnp.asarray(make_phi_matrix(rng, 24, 96, phi=0.5))
    beta = compute_beta(96)
    rowmax = np.max(np.abs(np.asarray(a)), axis=1)
    prev = None
    for kk in range(1, k + 1):
        s = SPLITTERS[name](a, kk)
        res = np.max(np.abs(np.asarray(residual(s, a))), axis=1)
        bound = rowmax * 2.0 ** (-beta * kk + 2)
        assert np.all(res <= bound + 1e-300), (name, kk)
        if prev is not None:
            assert np.all(res <= prev + 1e-300)
        prev = res


def _bounded_spread_matrix(rng, m, n):
    """Entries with |a_ij| in [0.5, 1): exponent spread < 1 bit per row, so
    k*beta >= 54 bits covers the full 53-bit mantissa of every element."""
    sign = np.where(rng.uniform(size=(m, n)) < 0.5, -1.0, 1.0)
    return sign * rng.uniform(0.5, 1.0, (m, n))


def test_bitmask_split_is_exact_sum(rng):
    """Bitmask slices reconstruct A bit-exactly once k*beta covers the
    mantissa (53 bits + in-row exponent spread)."""
    a = jnp.asarray(_bounded_spread_matrix(rng, 16, 32))
    s = split_bitmask(a, 9)  # 9*7 = 63 > 54 bits
    assert np.array_equal(np.asarray(reconstruct(s)), np.asarray(a))


def test_rn_const_split_is_exact_sum(rng):
    a = jnp.asarray(_bounded_spread_matrix(rng, 16, 32))
    s = split_rn_const(a, 10)  # 10 RN slices (6 bits each) cover > 54 bits
    assert np.array_equal(np.asarray(reconstruct(s)), np.asarray(a))


def test_geometric_scale_structure(rng):
    """scale[s] = base * 2^(-beta s) — required for group-EF accumulation."""
    a = jnp.asarray(make_phi_matrix(rng, 8, 64))
    for fn in (split_bitmask, split_rn_const):
        s = fn(a, 5)
        assert s.base is not None
        for i in range(5):
            expect = np.asarray(s.base) * 2.0 ** (-s.beta * (i + 1))
            np.testing.assert_array_equal(np.asarray(s.scale[i]), expect)
    s = split_rn(a, 5)
    assert s.base is None


def test_zero_rows_and_columns(rng):
    a = np.zeros((8, 16))
    a[3] = make_phi_matrix(rng, 1, 16)[0]
    s = split_rn_const(jnp.asarray(a), 6)
    assert np.all(np.isfinite(np.asarray(s.scale)))
    rec = np.asarray(reconstruct(s))
    assert np.array_equal(rec[a == 0], np.zeros_like(rec[a == 0]))
    res = np.abs(rec[3] - a[3])
    assert np.all(res <= np.max(np.abs(a[3])) * 2.0 ** (-7 * 6 + 2))
    z = split_bitmask(jnp.zeros((4, 4)), 3)
    assert np.all(np.asarray(z.digits) == 0)


def test_f32_inputs(rng):
    a32 = jnp.asarray(make_phi_matrix(rng, 16, 64, dtype=np.float32))
    for fn in (split_bitmask, split_rn, split_rn_const):
        s = fn(a32, 5)
        assert s.scale.dtype == jnp.float32
        res = np.abs(np.asarray(residual(s, a32)))
        rowmax = np.max(np.abs(np.asarray(a32)), axis=1, keepdims=True)
        assert np.all(res <= rowmax * 2.0 ** (-7 * 5 + 2))


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 12), n=st.integers(1, 40), k=st.integers(1, 10),
    phi=st.floats(0.0, 3.0), seed=st.integers(0, 2**31),
)
def test_property_residual_bound_all_splitters(m, n, k, phi, seed):
    """Property: for random shapes/difficulties, every splitter satisfies the
    paper's per-slice residual bound and digit-range invariant."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(make_phi_matrix(rng, m, n, phi))
    beta = compute_beta(n)
    rowmax = np.max(np.abs(np.asarray(a)), axis=1)
    for name, fn in SPLITTERS.items():
        s = fn(a, k)
        d = np.asarray(s.digits, np.int32)
        lim = 2 ** beta - 1 if name == "bitmask" else 2 ** (beta - 1)
        assert np.max(np.abs(d), initial=0) <= lim
        res = np.max(np.abs(np.asarray(residual(s, a))), axis=1)
        assert np.all(res <= rowmax * 2.0 ** (-beta * k + 2) + 1e-300)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31), k=st.integers(2, 9))
def test_property_mixed_magnitudes(seed, k):
    """Rows mixing huge/tiny/zero entries keep exactness guarantees."""
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((6, 24))
    a[0] *= 1e18
    a[1] *= 1e-18
    a[2, ::2] = 0.0
    a[3] = 0.0
    aj = jnp.asarray(a)
    for fn in (split_bitmask, split_rn_const):
        s = fn(aj, k)
        assert np.all(np.isfinite(np.asarray(s.scale)))
        res = np.abs(np.asarray(residual(s, aj)))
        rowmax = np.max(np.abs(a), axis=1, keepdims=True)
        assert np.all(res <= rowmax * 2.0 ** (-s.beta * k + 2) + 1e-300)


# ---------------------------------------------------------------------------
# oz2 constant-scaling splits
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", list(OZ2_SPLITTERS))
@pytest.mark.parametrize("axis", [0, 1])
def test_oz2_shared_grid_structure(rng, name, axis):
    """One grid for the whole matrix: every row's scale vector is the same
    scalar, exposed as ``gbase``, and the geometric ladder holds."""
    a = jnp.asarray(make_phi_matrix(rng, 24, 48, phi=1.5))
    s = OZ2_SPLITTERS[name](a, 6, axis=axis)
    sc = np.asarray(s.scale)
    assert s.gbase is not None and np.asarray(s.gbase).shape == ()
    base = np.asarray(s.base)
    assert np.all(base == np.asarray(s.gbase))          # broadcast scalar
    for i in range(6):
        np.testing.assert_array_equal(sc[i], base * 2.0 ** (-s.beta * (i + 1)))
    d = np.asarray(s.digits, np.int32)
    assert np.max(np.abs(d)) <= DIGIT_LIMIT[name](s.beta)


def test_oz2_global_anchor_rows_below_grid(rng):
    """Rows far below the global maximum fall off the shared grid: their
    digits are exactly zero and the residual is the row itself — the
    documented price of constant scaling (docs/algorithms.md)."""
    a = rng.standard_normal((6, 32))
    a[2] *= 2.0 ** -120          # below the k*beta window of the top row
    aj = jnp.asarray(a)
    for fn in OZ2_SPLITTERS.values():
        s = fn(aj, 8)            # 56-bit window
        d = np.asarray(s.digits, np.int32)
        assert np.all(d[:, 2, :] == 0)
        res = np.asarray(residual(s, aj))
        np.testing.assert_array_equal(res[2], a[2])
    # per-row splitters keep resolving such rows
    s = split_rn_const(aj, 8)
    assert np.any(np.asarray(s.digits, np.int32)[:, 2, :] != 0)


def test_oz2_zero_matrix_and_batch(rng):
    z = split_oz2(jnp.zeros((4, 8)), 3)
    assert np.all(np.asarray(z.digits) == 0)
    assert np.all(np.isfinite(np.asarray(z.scale)))
    ab = jnp.asarray(rng.standard_normal((3, 5, 16)))
    s = split_oz2(ab, 4)
    assert np.asarray(s.gbase).shape == (3,)
    # per-batch grids: each batch element anchored at its own global max
    for i in range(3):
        si = split_oz2(ab[i], 4)
        np.testing.assert_array_equal(np.asarray(s.digits)[:, i],
                                      np.asarray(si.digits))


# ---------------------------------------------------------------------------
# property-based EFT invariants (satellite: splitter error-free-transform
# guarantees for every splitter, across dtypes/shapes/batch dims)
# ---------------------------------------------------------------------------

def _sequential_reconstruct(s) -> np.ndarray:
    """Slice sum in ascending slice order with numpy (deterministic
    addition order — each partial sum is a rounding of `a` to that slice's
    grid, hence exactly representable; see the EFT argument below)."""
    d = np.asarray(s.digits, np.float64)
    sc = np.asarray(s.scale, np.float64)
    rec = np.zeros(d.shape[1:], np.float64)
    for i in range(d.shape[0]):
        rec = rec + d[i] * (sc[i][..., :, None] if s.axis == 0
                            else sc[i][..., None, :])
    return rec


# ---------------------------------------------------------------------------
# fast2 (improved-scaling) oz2 splits — per-row pow2 equilibration onto a
# constant shared grid (spec token :fast2)
# ---------------------------------------------------------------------------

PER_ROW_OF = {"oz2_rn_fast2": split_rn_const, "oz2_bitmask_fast2": split_bitmask}


@pytest.mark.parametrize("name", list(FAST2_SPLITTERS))
@pytest.mark.parametrize("axis", [0, 1])
def test_fast2_digits_bitwise_equal_per_row_splitter(rng, name, axis):
    """The equilibration a_hat = a / rho_i is an EXACT power-of-two rescale,
    so fast2 digits are bitwise the per-row splitter's — the constant grid
    costs nothing in digit quality (Kawakami-Takahashi improved scaling)."""
    a = jnp.asarray(make_phi_matrix(rng, 24, 48, phi=3.0))
    s2 = FAST2_SPLITTERS[name](a, 7, axis=axis)
    sp = PER_ROW_OF[name](a, 7, axis=axis)
    np.testing.assert_array_equal(np.asarray(s2.digits), np.asarray(sp.digits))
    np.testing.assert_array_equal(np.asarray(s2.scale), np.asarray(sp.scale))
    np.testing.assert_array_equal(np.asarray(s2.base), np.asarray(sp.base))


@pytest.mark.parametrize("name", list(FAST2_SPLITTERS))
def test_fast2_grid_structure(rng, name):
    """fast2 structure: scalar gbase == 2 (the equilibrated shared base),
    per-row base a power of two, and the unscale ratio base/gbase an exact
    power of two (so the post-ladder diag rescale commutes bitwise)."""
    a = jnp.asarray(make_phi_matrix(rng, 16, 64, phi=2.0))
    s = FAST2_SPLITTERS[name](a, 6)
    assert s.gbase is not None and np.asarray(s.gbase).shape == ()
    assert float(np.asarray(s.gbase)) == 2.0
    base = np.asarray(s.base)
    mant, _ = np.frexp(base[base != 0])
    assert np.all(mant == 0.5)                       # pow2 base
    ratio = base / np.asarray(s.gbase)
    mant, _ = np.frexp(ratio[ratio != 0])
    assert np.all(mant == 0.5)                       # pow2 unscale ratio
    # geometric ladder per row, like the shared-grid splits
    sc = np.asarray(s.scale)
    for i in range(6):
        np.testing.assert_array_equal(sc[i], base * 2.0 ** (-s.beta * (i + 1)))
    # batch: one gbase per batch element, still the constant 2
    ab = jnp.asarray(rng.standard_normal((3, 5, 16)))
    sb = FAST2_SPLITTERS[name](ab, 4)
    assert np.asarray(sb.gbase).shape == (3,)
    assert np.all(np.asarray(sb.gbase) == 2.0)


@pytest.mark.parametrize("name", list(FAST2_SPLITTERS))
def test_fast2_rowmax_reduce_grid_agreement(rng, name):
    """Mesh-agreeability: contraction shards see only a column slice of A,
    but the ``rowmax_reduce`` hook (a pmax over shards) hands every shard
    the SAME per-row maxima — so shard grids, bases and digits match the
    unsharded split exactly (the property @mesh/int32 relies on)."""
    a = np.asarray(make_phi_matrix(rng, 12, 64, phi=2.0))
    aj = jnp.asarray(a)
    full = FAST2_SPLITTERS[name](aj, 6)
    shards = [aj[:, :32], aj[:, 32:]]
    # simulated pmax: the true cross-shard reduction of the per-row maxima
    global_rowmax = jnp.max(jnp.abs(aj), axis=1)
    reduce_fn = lambda local: jnp.maximum(local, global_rowmax)
    for i, sh in enumerate(shards):
        s = FAST2_SPLITTERS[name](sh, 6, rowmax_reduce=reduce_fn)
        np.testing.assert_array_equal(np.asarray(s.base), np.asarray(full.base))
        np.testing.assert_array_equal(np.asarray(s.gbase),
                                      np.asarray(full.gbase))
        np.testing.assert_array_equal(np.asarray(s.scale),
                                      np.asarray(full.scale))
        np.testing.assert_array_equal(
            np.asarray(s.digits), np.asarray(full.digits)[:, :, 32 * i:32 * (i + 1)])


def test_fast2_worked_example_micro_case():
    """Pinned worked example of the improved scaling (Kawakami & Takahashi
    style): a 2x2 matrix with exactly-representable grid values, checked
    against hand-computed digits, bases and unscale ratios.

    n=2 => beta=7.  Row 0 = [1.5, 0.25]: rowmax 1.5, 2^ceil = 2, base = 4,
    mu = 4*2^-7 = 1/32, RN digits round(a*32) = [48, 8] (exact, so slices
    2.. are zero).  Row 1 = [-0.375, 0.5]: base = 1, mu = 1/128, digits
    [-48, 64].  Equilibrated base gbase = 2; unscale ratios base/gbase =
    [2, 0.5]."""
    a = jnp.asarray(np.array([[1.5, 0.25], [-0.375, 0.5]]))
    s = split_oz2_fast2(a, 3)
    assert s.beta == 7
    np.testing.assert_array_equal(np.asarray(s.base), [4.0, 1.0])
    assert float(np.asarray(s.gbase)) == 2.0
    np.testing.assert_array_equal(np.asarray(s.base) / np.asarray(s.gbase),
                                  [2.0, 0.5])
    d = np.asarray(s.digits, np.int32)
    np.testing.assert_array_equal(d[0], [[48, 8], [-48, 64]])
    np.testing.assert_array_equal(d[1:], 0)
    np.testing.assert_array_equal(np.asarray(reconstruct(s)), np.asarray(a))
    np.testing.assert_array_equal(np.asarray(residual(s, a)), 0.0)
    # bitmask flavour: base = 2*2^floor, truncation digits
    sb = split_oz2_bitmask_fast2(a, 3)
    np.testing.assert_array_equal(np.asarray(sb.base), [2.0, 1.0])
    assert float(np.asarray(sb.gbase)) == 2.0
    db = np.asarray(sb.digits, np.int32)
    np.testing.assert_array_equal(db[0], [[96, 16], [-48, 64]])
    np.testing.assert_array_equal(np.asarray(reconstruct(sb)), np.asarray(a))


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 10), n=st.integers(1, 32), k=st.integers(1, 9),
    nb=st.integers(0, 2), axis=st.integers(0, 1),
    dtype=st.sampled_from(["f32", "f64"]), phi=st.floats(0.0, 3.0),
    seed=st.integers(0, 2**31),
)
def test_property_eft_invariants_all_splitters(m, n, k, nb, axis, dtype,
                                               phi, seed):
    """The error-free-transform contract of every splitter, every dtype,
    with and without batch dims:

      * ``reconstruct(split) + residual == a`` EXACTLY (bitwise): each
        partial slice sum is the input rounded/truncated to that slice's
        power-of-two grid — representable — so the additions and the final
        residual subtraction never round;
      * every scale is a power of two (frexp mantissa exactly 0.5);
      * every digit is int-representable within the splitter's mantissa
        budget (trunc: 2^beta - 1; RN: 2^(beta-1)).
    """
    rng = np.random.default_rng(seed)
    np_dtype = np.float32 if dtype == "f32" else np.float64
    batch = (2,) * nb
    a = make_phi_matrix(rng, int(np.prod(batch, initial=1)) * m, n, phi,
                        dtype=np_dtype).reshape(batch + (m, n))
    aj = jnp.asarray(a)
    wide = np.float64
    for name, fn in ALL_SPLITTERS.items():
        s = fn(aj, k, axis=axis)
        # digits within the mantissa budget
        d = np.asarray(s.digits, np.int32)
        assert np.max(np.abs(d), initial=0) <= DIGIT_LIMIT[name](s.beta), \
            name
        # scales pow2-exact
        sc = np.asarray(s.scale)
        mant, _ = np.frexp(sc[sc != 0])
        assert np.all(mant == 0.5), name
        # exact EFT: reconstruct + residual == a, bitwise
        rec = _sequential_reconstruct(s)
        res = a.astype(wide) - rec
        assert np.array_equal(rec + res, a.astype(wide)), name
        # and the residual is the scheme's V_k: below the last grid.
        # The fast2 splits are NOT widened to the global anchor — their
        # per-row equilibrated grid must satisfy the same tight per-row
        # contract as the per-row splitters (the whole point of fast2).
        limit = 2.0 ** (-s.beta * k + 2)
        anchor = np.max(np.abs(a), axis=-1 if axis == 0 else -2,
                        keepdims=True).astype(wide)
        if name.startswith("oz2") and not name.endswith("_fast2"):
            anchor = np.max(anchor, axis=(-1, -2), keepdims=True)
        assert np.all(np.abs(res) <= anchor * limit + 1e-300), name


# ---------------------------------------------------------------------------
# sign-magnitude splits — ozimmu_sm_b / ozimmu_sm_h (satellite: property
# invariants for the unsigned-magnitude digit family)
# ---------------------------------------------------------------------------

from repro.core import compute_beta_sm, split_sm, sm_decode


def test_compute_beta_sm_model():
    """beta_sm = min(8, (31 - clog2 n)//2): one more digit bit than eq. (4)
    wherever the int32 budget allows — the unsigned trailing magnitudes
    spend no sign bit — and always int32-overflow safe."""
    assert compute_beta_sm(2) == 8
    assert compute_beta_sm(256) == 8
    assert compute_beta_sm(2 ** 15) == 8
    assert compute_beta_sm(2 ** 16) == 7
    assert compute_beta_sm(2 ** 18) == 6
    for n in (2, 256, 2 ** 15, 2 ** 16, 2 ** 18, 2 ** 29):
        beta = compute_beta_sm(n)
        assert n * (2 ** beta - 1) ** 2 < 2 ** 31     # int32 MAC safety
    with pytest.raises(ValueError):
        compute_beta_sm(2 ** 30)


@pytest.mark.parametrize("axis", [0, 1])
def test_sm_digit_ranges_and_sign_recovery(rng, axis):
    """Decoded digits: signed leading slice within +-2^(beta-1), trailing
    slices UNSIGNED in [0, 2^beta - 1]; the operand's sign is recoverable
    from the leading slice alone (a < 0  <=>  lead digit < 0)."""
    a = np.asarray(make_phi_matrix(rng, 32, 48, phi=2.0))
    a[3, 7] = 0.0
    aj = jnp.asarray(a)
    s = split_sm(aj, 8, axis=axis)
    assert s.signmag and s.digits.dtype == jnp.int8
    d = np.asarray(sm_decode(s.digits), np.int32)
    assert -(2 ** (s.beta - 1)) <= d[0].min()
    assert d[0].max() <= 2 ** (s.beta - 1) - 1
    assert d[1:].min() >= 0 and d[1:].max() <= 2 ** s.beta - 1
    np.testing.assert_array_equal(d[0] < 0, a < 0)


def test_sm_scales_geometric_pow2(rng):
    """scale[s] = base * 2^(-beta s) with base = 4 * 2^floor(log2 rowmax)
    — all powers of two (required for the exact pow2 scale folds that
    keep @mesh/int32 bitwise)."""
    a = jnp.asarray(make_phi_matrix(rng, 16, 64, phi=2.0))
    s = split_sm(a, 6)
    base = np.asarray(s.base)
    mant, _ = np.frexp(base)
    assert np.all(mant == 0.5)
    rowmax = np.max(np.abs(np.asarray(a)), axis=1)
    np.testing.assert_array_equal(
        base, 4.0 * 2.0 ** np.floor(np.log2(rowmax)))
    sc = np.asarray(s.scale)
    for i in range(6):
        np.testing.assert_array_equal(sc[i], base * 2.0 ** (-s.beta * (i + 1)))
    mant, _ = np.frexp(sc[sc != 0])
    assert np.all(mant == 0.5)


def test_sm_reconstruct_exact_when_covered(rng):
    """k slices cover beta*k - 1 bits; at k=8, beta=8 that is 63 > 54, so
    the two's-complement digit sum reconstructs A bit-exactly (signed
    entries included)."""
    a = jnp.asarray(_bounded_spread_matrix(rng, 16, 32))
    s = split_sm(a, 8)
    assert np.array_equal(np.asarray(reconstruct(s)), np.asarray(a))
    assert np.all(np.asarray(residual(s, a)) == 0.0)


def test_sm_tiny_negative_lead_residual_clamp():
    """Pinned regression for the negative-fraction hazard: for a tiny
    negative entry the lead residual 1 - eps is not representable and
    rounds to exactly 1.0; the digit clamp must emit the true
    infinite-precision cascade (lead -1, trailing all 2^beta - 1) instead
    of an overflowed wrapped digit that loses a whole scale_1 of value."""
    a = jnp.asarray(np.array([[0.75, -2.0 ** -60]]))   # n=2 -> beta=8
    s = split_sm(a, 4, axis=0)
    d = np.asarray(sm_decode(s.digits), np.int32)
    assert d[0, 0, 1] == -1
    np.testing.assert_array_equal(d[1:, 0, 1], 255)
    # EFT contract still exact, and the residual stays at the k-digit
    # truncation level (the cascade sums to -base * 2^(-beta k)) plus the
    # half-ulp lead rounding — NOT a scale_1-sized loss
    rec = np.asarray(reconstruct(s))
    res = np.asarray(residual(s, a))
    assert np.array_equal(rec + res, np.asarray(a))
    base = float(np.asarray(s.base)[0])
    assert abs(res[0, 1]) <= (2.0 ** (-s.beta * 4) + 2.0 ** -53) * base


def test_sm_rowmax_reduce_grid_agreement(rng):
    """Mesh-agreeability: shards holding a column slice of A agree with
    the unsharded split bitwise once ``rowmax_reduce`` (the @mesh pmax
    hook) hands them the global per-row maxima."""
    a = np.asarray(make_phi_matrix(rng, 12, 64, phi=2.0))
    aj = jnp.asarray(a)
    full = split_sm(aj, 6)
    global_rowmax = jnp.max(jnp.abs(aj), axis=1)
    reduce_fn = lambda local: jnp.maximum(local, global_rowmax)
    for i, sh in enumerate([aj[:, :32], aj[:, 32:]]):
        s = split_sm(sh, 6, rowmax_reduce=reduce_fn)
        np.testing.assert_array_equal(np.asarray(s.base),
                                      np.asarray(full.base))
        np.testing.assert_array_equal(np.asarray(s.scale),
                                      np.asarray(full.scale))
        np.testing.assert_array_equal(
            np.asarray(s.digits),
            np.asarray(full.digits)[:, :, 32 * i:32 * (i + 1)])


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 10), n=st.integers(1, 32), k=st.integers(1, 9),
    nb=st.integers(0, 2), axis=st.integers(0, 1),
    dtype=st.sampled_from(["f32", "f64"]), phi=st.floats(0.0, 3.0),
    seed=st.integers(0, 2**31),
)
def test_property_sm_eft_invariants(m, n, k, nb, axis, dtype, phi, seed):
    """The sm splitter's contract across dtypes/shapes/batch dims:
    decoded digit budgets (signed lead, unsigned trail), pow2 scales,
    ``reconstruct + residual == a`` BITWISE, and the residual under the
    documented grid bound.  The bound carries one extra term,
    ``2^(2 - beta - p)`` of the rowmax: the tiny-negative lead residual
    rounds by up to half an ulp of 1.0 before the digit-cascade clamp
    reproduces the true extraction (see ``test_sm_tiny_negative_..``)."""
    rng = np.random.default_rng(seed)
    np_dtype = np.float32 if dtype == "f32" else np.float64
    p_bits = 24 if dtype == "f32" else 53
    batch = (2,) * nb
    a = make_phi_matrix(rng, int(np.prod(batch, initial=1)) * m, n, phi,
                        dtype=np_dtype).reshape(batch + (m, n))
    aj = jnp.asarray(a)
    s = split_sm(aj, k, axis=axis)
    assert s.signmag
    d = np.asarray(sm_decode(s.digits), np.int32)
    assert -(2 ** (s.beta - 1)) <= d[0].min(initial=0)
    assert d[0].max(initial=0) <= 2 ** (s.beta - 1) - 1
    if k > 1:
        assert d[1:].min(initial=0) >= 0
        assert d[1:].max(initial=0) <= 2 ** s.beta - 1
    sc = np.asarray(s.scale)
    mant, _ = np.frexp(sc[sc != 0])
    assert np.all(mant == 0.5)
    rec = np.asarray(reconstruct(s, jnp.float64))
    res = a.astype(np.float64) - rec
    assert np.array_equal(rec + res, a.astype(np.float64))
    rowmax = np.max(np.abs(a), axis=-1 if axis == 0 else -2,
                    keepdims=True).astype(np.float64)
    limit = 2.0 ** (-s.beta * k + 2) + 2.0 ** (2 - s.beta - p_bits)
    assert np.all(np.abs(res) <= rowmax * limit + 1e-300)
