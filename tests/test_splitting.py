"""Unit + property tests for the three splitting strategies (Algs. 3, 5, 8)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests.conftest import hypothesis_or_stubs

given, settings, st = hypothesis_or_stubs()

from repro.core import (compute_beta, compute_r, split_bitmask, split_rn,
                        split_rn_const, reconstruct, residual)
from tests.conftest import make_phi_matrix

SPLITTERS = {"bitmask": split_bitmask, "rn": split_rn, "rn_const": split_rn_const}


def test_compute_beta_matches_paper():
    # beta = min(7, floor((31 - log2 n)/2)), eq. (4)
    assert compute_beta(256) == 7
    assert compute_beta(1024) == 7
    assert compute_beta(2**17) == 7
    assert compute_beta(2**18) == 6       # (31-18)//2
    assert compute_beta(2**20) == 5
    assert compute_beta(2**29) == 1
    with pytest.raises(ValueError):
        compute_beta(2**30)


def test_compute_r_matches_paper():
    # r = max(1, 2^(31 - 2 beta - ceil(log2 n))), eq. (12)
    assert compute_r(4096, 7) == 2 ** (31 - 14 - 12)
    assert compute_r(256, 7) == 2 ** (31 - 14 - 8)
    assert compute_r(2**20, 5) == 2 ** (31 - 10 - 20)
    assert compute_r(2**29, 1) == 1


@pytest.mark.parametrize("name", list(SPLITTERS))
@pytest.mark.parametrize("axis", [0, 1])
def test_digit_ranges(rng, name, axis):
    a = jnp.asarray(make_phi_matrix(rng, 32, 48, phi=1.0))
    s = SPLITTERS[name](a, 8, axis=axis)
    d = np.asarray(s.digits, dtype=np.int32)
    if name == "bitmask":
        assert np.max(np.abs(d)) <= 2 ** s.beta - 1          # eq. (5) digits
    else:
        assert np.max(np.abs(d)) <= 2 ** (s.beta - 1)        # RN digits
    assert s.digits.dtype == jnp.int8


@pytest.mark.parametrize("name", list(SPLITTERS))
def test_scales_are_powers_of_two(rng, name):
    a = jnp.asarray(make_phi_matrix(rng, 16, 64, phi=2.0))
    s = SPLITTERS[name](a, 6)
    sc = np.asarray(s.scale)
    m, e = np.frexp(sc[sc != 0])
    assert np.all(m == 0.5)


@pytest.mark.parametrize("name,k", [("bitmask", 8), ("rn", 8), ("rn_const", 8)])
def test_residual_decreases_geometrically(rng, name, k):
    """|V_s| < 2^(-beta s + 1) g e^T — eq. (16)-ish contraction per slice."""
    a = jnp.asarray(make_phi_matrix(rng, 24, 96, phi=0.5))
    beta = compute_beta(96)
    rowmax = np.max(np.abs(np.asarray(a)), axis=1)
    prev = None
    for kk in range(1, k + 1):
        s = SPLITTERS[name](a, kk)
        res = np.max(np.abs(np.asarray(residual(s, a))), axis=1)
        bound = rowmax * 2.0 ** (-beta * kk + 2)
        assert np.all(res <= bound + 1e-300), (name, kk)
        if prev is not None:
            assert np.all(res <= prev + 1e-300)
        prev = res


def _bounded_spread_matrix(rng, m, n):
    """Entries with |a_ij| in [0.5, 1): exponent spread < 1 bit per row, so
    k*beta >= 54 bits covers the full 53-bit mantissa of every element."""
    sign = np.where(rng.uniform(size=(m, n)) < 0.5, -1.0, 1.0)
    return sign * rng.uniform(0.5, 1.0, (m, n))


def test_bitmask_split_is_exact_sum(rng):
    """Bitmask slices reconstruct A bit-exactly once k*beta covers the
    mantissa (53 bits + in-row exponent spread)."""
    a = jnp.asarray(_bounded_spread_matrix(rng, 16, 32))
    s = split_bitmask(a, 9)  # 9*7 = 63 > 54 bits
    assert np.array_equal(np.asarray(reconstruct(s)), np.asarray(a))


def test_rn_const_split_is_exact_sum(rng):
    a = jnp.asarray(_bounded_spread_matrix(rng, 16, 32))
    s = split_rn_const(a, 10)  # 10 RN slices (6 bits each) cover > 54 bits
    assert np.array_equal(np.asarray(reconstruct(s)), np.asarray(a))


def test_geometric_scale_structure(rng):
    """scale[s] = base * 2^(-beta s) — required for group-EF accumulation."""
    a = jnp.asarray(make_phi_matrix(rng, 8, 64))
    for fn in (split_bitmask, split_rn_const):
        s = fn(a, 5)
        assert s.base is not None
        for i in range(5):
            expect = np.asarray(s.base) * 2.0 ** (-s.beta * (i + 1))
            np.testing.assert_array_equal(np.asarray(s.scale[i]), expect)
    s = split_rn(a, 5)
    assert s.base is None


def test_zero_rows_and_columns(rng):
    a = np.zeros((8, 16))
    a[3] = make_phi_matrix(rng, 1, 16)[0]
    s = split_rn_const(jnp.asarray(a), 6)
    assert np.all(np.isfinite(np.asarray(s.scale)))
    rec = np.asarray(reconstruct(s))
    assert np.array_equal(rec[a == 0], np.zeros_like(rec[a == 0]))
    res = np.abs(rec[3] - a[3])
    assert np.all(res <= np.max(np.abs(a[3])) * 2.0 ** (-7 * 6 + 2))
    z = split_bitmask(jnp.zeros((4, 4)), 3)
    assert np.all(np.asarray(z.digits) == 0)


def test_f32_inputs(rng):
    a32 = jnp.asarray(make_phi_matrix(rng, 16, 64, dtype=np.float32))
    for fn in (split_bitmask, split_rn, split_rn_const):
        s = fn(a32, 5)
        assert s.scale.dtype == jnp.float32
        res = np.abs(np.asarray(residual(s, a32)))
        rowmax = np.max(np.abs(np.asarray(a32)), axis=1, keepdims=True)
        assert np.all(res <= rowmax * 2.0 ** (-7 * 5 + 2))


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 12), n=st.integers(1, 40), k=st.integers(1, 10),
    phi=st.floats(0.0, 3.0), seed=st.integers(0, 2**31),
)
def test_property_residual_bound_all_splitters(m, n, k, phi, seed):
    """Property: for random shapes/difficulties, every splitter satisfies the
    paper's per-slice residual bound and digit-range invariant."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(make_phi_matrix(rng, m, n, phi))
    beta = compute_beta(n)
    rowmax = np.max(np.abs(np.asarray(a)), axis=1)
    for name, fn in SPLITTERS.items():
        s = fn(a, k)
        d = np.asarray(s.digits, np.int32)
        lim = 2 ** beta - 1 if name == "bitmask" else 2 ** (beta - 1)
        assert np.max(np.abs(d), initial=0) <= lim
        res = np.max(np.abs(np.asarray(residual(s, a))), axis=1)
        assert np.all(res <= rowmax * 2.0 ** (-beta * k + 2) + 1e-300)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31), k=st.integers(2, 9))
def test_property_mixed_magnitudes(seed, k):
    """Rows mixing huge/tiny/zero entries keep exactness guarantees."""
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((6, 24))
    a[0] *= 1e18
    a[1] *= 1e-18
    a[2, ::2] = 0.0
    a[3] = 0.0
    aj = jnp.asarray(a)
    for fn in (split_bitmask, split_rn_const):
        s = fn(aj, k)
        assert np.all(np.isfinite(np.asarray(s.scale)))
        res = np.abs(np.asarray(residual(s, aj)))
        rowmax = np.max(np.abs(a), axis=1, keepdims=True)
        assert np.all(res <= rowmax * 2.0 ** (-s.beta * k + 2) + 1e-300)
