"""Flash attention (custom-VJP) vs naive full-softmax reference — values AND
gradients, across GQA/MQA, Dv != D (MLA), causal/window, uneven chunks."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.layers import attention_flash


def naive(q, k, v, causal=True, window=None):
    B, Lq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Lq, KV, G, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * D ** -0.5
    qpos, kpos = jnp.arange(Lq), jnp.arange(k.shape[1])
    m = jnp.ones((Lq, k.shape[1]), bool)
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window:
        m &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Lq, H, v.shape[-1]).astype(
        q.dtype)


CASES = [
    # B, L, H, KV, D, Dv, causal, window, qc, kc
    (2, 17, 4, 2, 8, 8, True, None, 8, 8),     # GQA, uneven chunks
    (1, 33, 4, 1, 8, 12, True, 7, 16, 8),      # MQA, Dv != D, windowed
    (2, 16, 2, 2, 8, 8, False, None, 8, 16),   # bidirectional (cross-attn)
    (1, 8, 8, 4, 16, 16, True, None, 64, 64),  # chunk > L
]


@pytest.mark.parametrize("B,L,H,KV,D,Dv,causal,window,qc,kc", CASES)
def test_flash_matches_naive_fwd_bwd(B, L, H, KV, D, Dv, causal, window,
                                     qc, kc):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, L, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, L, KV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, L, KV, Dv)), jnp.float32)

    out = attention_flash(q, k, v, causal=causal, window=window,
                          q_chunk=qc, kv_chunk=kc)
    ref = naive(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)

    def loss_flash(q, k, v):
        o = attention_flash(q, k, v, causal=causal, window=window,
                            q_chunk=qc, kv_chunk=kc)
        return jnp.sum(jnp.sin(o))  # non-trivial cotangent

    def loss_naive(q, k, v):
        return jnp.sum(jnp.sin(naive(q, k, v, causal=causal, window=window)))

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(g1, g2, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-4, err_msg=f"d{name}")
